"""Continuous-batching generation subsystem tests.

- single-wave equivalence: batch <= wave, same rng -> genserve reproduces
  ``rollout.generate`` token-for-token (valid positions; sampled and
  greedy, chunked and unchunked);
- slot-recycling correctness: batch >> wave under greedy decoding ->
  every recycled request's output equals the single-wave reference
  (per-slot cache positions, scatter injection, ring windows);
- EOS edge: a prompt already ending in EOS yields an all-invalid mask on
  both paths (the shared ``models.sampling`` aliveness helper);
- occupancy parity: uniform lengths -> measured slot-table occupancy
  equals ``core.plan`` predictions exactly; skewed budgets stay within
  the ideal bound;
- engine integration: the TaskKind.GEN executor produces per-wave Event
  timeline entries comparable against the cost model's decode_wave.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import plan as plan_mod
from repro.data.synthetic import AdditionTask, EOS, VOCAB_SIZE
from repro.genserve import adapter as genserve
from repro.genserve.decoder import GenServeConfig, serve
from repro.genserve.scheduler import FREE, Request, RequestQueue, SlotTable
from repro.models import transformer as T
from repro.models.config import LayerSpec, ModelConfig
from repro.rl import rollout
from repro.rl.trainer import RLConfig, RLTrainer

KEY = jax.random.PRNGKey(0)
P, N = 8, 6


def tiny_cfg(window=None):
    return ModelConfig(name=f"gs-tiny-w{window}", n_layers=2, d_model=64,
                       n_heads=2, n_kv_heads=2, head_dim=32, d_ff=128,
                       vocab_size=VOCAB_SIZE, dtype="float32",
                       pattern=(LayerSpec(window=window),))


@pytest.fixture(scope="module")
def setup():
    cfg = tiny_cfg()
    return cfg, T.init_params(KEY, cfg)


def prompts_for(n, key=3, cfg=None):
    return jax.random.randint(jax.random.PRNGKey(key), (n, P), 0,
                              (cfg or tiny_cfg()).vocab_size, jnp.int32)


def assert_rollout_equal(ref, got, atol=1e-4):
    mr, mg = np.asarray(ref["mask"]), np.asarray(got["mask"])
    np.testing.assert_array_equal(mr, mg)
    np.testing.assert_array_equal(
        np.asarray(ref["gen_tokens"]) * mr.astype(np.int32),
        np.asarray(got["gen_tokens"]) * mg.astype(np.int32))
    np.testing.assert_allclose(np.asarray(ref["logprobs"]) * mr,
                               np.asarray(got["logprobs"]) * mg,
                               rtol=1e-4, atol=atol)
    np.testing.assert_array_equal(
        np.asarray(ref["sequences"])[:, :P], np.asarray(got["sequences"])[:, :P])


@pytest.mark.parametrize("chunk", [1, 3])
def test_single_wave_equivalence_sampled(setup, chunk):
    """batch == wave, same rng -> identical sequences/logprobs/mask."""
    cfg, params = setup
    prompts = prompts_for(4)
    sampler = rollout.SamplerConfig(max_new_tokens=N, temperature=1.0,
                                    eos_token=EOS)
    ref = rollout.generate(params, cfg, prompts, jax.random.PRNGKey(7),
                           sampler)
    gcfg = GenServeConfig(wave=4, max_new_tokens=N, decode_chunk=chunk,
                          eos_token=EOS)
    got, stats = serve(params, cfg, prompts, jax.random.PRNGKey(7), gcfg)
    assert_rollout_equal(ref, got)
    assert stats["prefills"] == 1 and stats["admitted"] == 4


def test_single_wave_equivalence_greedy_padded_wave(setup):
    """batch < wave: padded prefill rows must not disturb real requests."""
    cfg, params = setup
    prompts = prompts_for(3)
    sampler = rollout.SamplerConfig(max_new_tokens=N, greedy=True,
                                    eos_token=EOS)
    ref = rollout.generate(params, cfg, prompts, jax.random.PRNGKey(2),
                           sampler)
    gcfg = GenServeConfig(wave=5, max_new_tokens=N, greedy=True,
                          eos_token=EOS)
    got, _ = serve(params, cfg, prompts, jax.random.PRNGKey(2), gcfg)
    assert_rollout_equal(ref, got)


@pytest.mark.parametrize("window", [None, 4])
def test_slot_recycling_matches_reference(window):
    """batch >> wave, greedy: recycled slots (fresh cache rows, per-slot
    positions — including ring-buffer windows) reproduce the single-wave
    reference for every request."""
    cfg = tiny_cfg(window=window)
    params = T.init_params(KEY, cfg)
    prompts = prompts_for(14, key=5, cfg=cfg)
    sampler = rollout.SamplerConfig(max_new_tokens=N, greedy=True,
                                    eos_token=3)
    ref = rollout.generate(params, cfg, prompts, jax.random.PRNGKey(1),
                           sampler)
    gcfg = GenServeConfig(wave=4, max_new_tokens=N, greedy=True, eos_token=3)
    got, stats = serve(params, cfg, prompts, jax.random.PRNGKey(1), gcfg)
    assert_rollout_equal(ref, got)
    assert stats["admitted"] == stats["retired"] == 14
    assert stats["prefills"] >= 2          # slots were actually recycled
    assert stats["wave"] == 4
    assert max(stats["occupancy_trace"]) <= 4


def test_prompt_ending_in_eos_starts_dead(setup):
    """Shared EOS edge: prompt's last token == EOS -> whole mask invalid
    on both the reference path and genserve."""
    cfg, params = setup
    prompts = np.array(prompts_for(4))
    # pin the alive/dead split instead of trusting the random prompts:
    # rows 0/2 must not end in EOS by luck of the PRNG stream
    prompts[0, -1] = prompts[2, -1] = 0
    prompts[1, -1] = EOS
    prompts[3, -1] = EOS
    sampler = rollout.SamplerConfig(max_new_tokens=N, eos_token=EOS)
    ref = rollout.generate(params, cfg, jnp.asarray(prompts),
                           jax.random.PRNGKey(4), sampler)
    gcfg = GenServeConfig(wave=4, max_new_tokens=N, eos_token=EOS)
    got, _ = serve(params, cfg, prompts, jax.random.PRNGKey(4), gcfg)
    for out in (ref, got):
        m = np.asarray(out["mask"])
        assert m[1].sum() == 0 and m[3].sum() == 0
        assert m[0, 0] == 1 and m[2, 0] == 1
    assert_rollout_equal(ref, got)


def test_per_request_budgets_and_skewed_occupancy(setup):
    """gen_lens caps each request; measured occupancy stays within the
    ideal continuous-batching bound from core.plan.predicted_occupancy."""
    cfg, params = setup
    B, W = 12, 4
    lens = [1, 1, 2, 2, 3, 3, N, N, N, N, N, N]
    prompts = prompts_for(B, key=9)
    gcfg = GenServeConfig(wave=W, max_new_tokens=N, greedy=True)
    got, stats = serve(params, cfg, prompts, KEY, gcfg, gen_lens=lens)
    np.testing.assert_array_equal(np.asarray(got["mask"]).sum(1), lens)
    ideal = plan_mod.predicted_occupancy(B, wave=W, gen_lens=lens)
    assert 0 < stats["mean_occupancy"] <= ideal + 1e-9
    # genserve does strictly less decode work than ceil(B/W) full waves
    assert stats["decode_steps"] < int(np.ceil(B / W)) * N


def test_no_decode_steps_when_all_finish_at_admission(setup):
    """Budget-1 requests finish with their prefill-sampled token; the
    engine must not burn any wave decode steps on them."""
    cfg, params = setup
    prompts = prompts_for(8, key=13)
    gcfg = GenServeConfig(wave=4, max_new_tokens=N, greedy=True,
                          decode_chunk=3)
    got, stats = serve(params, cfg, prompts, KEY, gcfg,
                       gen_lens=[1] * 8)
    np.testing.assert_array_equal(np.asarray(got["mask"]).sum(1),
                                  np.ones(8))
    assert stats["decode_steps"] == 0
    assert stats["prefills"] == 2 and stats["retired"] == 8


def test_uniform_occupancy_matches_decode_wave(setup):
    """No EOS, uniform budgets: every wave is full -> measured slot-table
    occupancy equals the cost model's decode_wave exactly."""
    cfg, params = setup
    B, W = 12, 4
    prompts = prompts_for(B, key=11)
    gcfg = GenServeConfig(wave=W, max_new_tokens=N, greedy=True)
    got, stats = serve(params, cfg, prompts, KEY, gcfg)
    assert np.asarray(got["mask"]).sum() == B * N
    assert stats["mean_occupancy"] == pytest.approx(
        plan_mod.predicted_occupancy(B, wave=W))
    assert stats["mean_occupancy"] == pytest.approx(
        float(plan_mod.decode_wave(B * W / B)))  # = W: full waves


@pytest.mark.parametrize("window", [None, 4])
def test_batched_decode_matches_vmapped_per_slot(window):
    """Tentpole parity: the batched wave decode (per-slot positions in
    one decode_step) is token-for-token identical to the legacy vmapped
    per-slot path, including recycled slots at distinct positions and
    ring windows."""
    cfg = tiny_cfg(window=window)
    params = T.init_params(KEY, cfg)
    prompts = prompts_for(14, key=5, cfg=cfg)
    kw = dict(wave=4, max_new_tokens=N, greedy=True, eos_token=3)
    ref, _ = serve(params, cfg, prompts, jax.random.PRNGKey(1),
                   GenServeConfig(decode_path="vmapped", **kw))
    got, stats = serve(params, cfg, prompts, jax.random.PRNGKey(1),
                       GenServeConfig(decode_path="batched", **kw))
    assert_rollout_equal(ref, got)
    assert stats["prefills"] >= 2          # slots were actually recycled


def test_batched_decode_matches_vmapped_gqa_softcap():
    """Same parity on a GQA + softcap config (the flash-decode kernel's
    hard cases), sampled rng path."""
    cfg = ModelConfig(name="gs-gqa", n_layers=2, d_model=64, n_heads=4,
                      n_kv_heads=2, head_dim=16, d_ff=128,
                      vocab_size=VOCAB_SIZE, dtype="float32",
                      attn_softcap=30.0)
    params = T.init_params(KEY, cfg)
    prompts = prompts_for(9, key=8, cfg=cfg)
    kw = dict(wave=3, max_new_tokens=N, eos_token=EOS)
    ref, _ = serve(params, cfg, prompts, jax.random.PRNGKey(6),
                   GenServeConfig(decode_path="vmapped", **kw))
    got, _ = serve(params, cfg, prompts, jax.random.PRNGKey(6),
                   GenServeConfig(decode_path="batched", **kw))
    assert_rollout_equal(ref, got)


@pytest.mark.parametrize("window", [None, 4])
def test_batched_decode_pallas_kernel_parity(window):
    """End-to-end: the batched wave decode under the pallas impl (the
    Sq == 1 flash-decode kernel seeing the whole wave, plus the prefill
    flash kernel) reproduces the jnp path on recycled slots."""
    from repro.models import attention as attn
    cfg = tiny_cfg(window=window)
    params = T.init_params(KEY, cfg)
    prompts = prompts_for(10, key=5, cfg=cfg)
    gcfg = GenServeConfig(wave=4, max_new_tokens=N, greedy=True,
                          eos_token=3)
    ref, _ = serve(params, cfg, prompts, jax.random.PRNGKey(1), gcfg)
    try:
        attn.set_attention_impl("pallas")
        got, stats = serve(params, cfg, prompts, jax.random.PRNGKey(1),
                           gcfg)
    finally:
        attn.set_attention_impl("jnp")
    assert_rollout_equal(ref, got, atol=1e-3)
    assert stats["prefills"] >= 2


def test_sjf_admission_policy():
    """admission="sjf": shortest budgets admitted first (queue order),
    greedy outputs still equal the FIFO run request-for-request."""
    q = RequestQueue([Request(0, 5), Request(1, 2), Request(2, 5),
                      Request(3, 1), Request(4, 2)], policy="sjf")
    order = [r.rid for r in q.pop(5)]
    assert order == [3, 1, 4, 0, 2]        # budget asc, arrival tie-break

    cfg = tiny_cfg()
    params = T.init_params(KEY, cfg)
    B, W = 10, 3
    lens = [N, 1, N, 2, 1, N, 2, N, 1, N]
    prompts = prompts_for(B, key=21)
    fifo, s_fifo = serve(params, cfg, prompts, KEY,
                         GenServeConfig(wave=W, max_new_tokens=N,
                                        greedy=True), gen_lens=lens)
    sjf, s_sjf = serve(params, cfg, prompts, KEY,
                       GenServeConfig(wave=W, max_new_tokens=N,
                                      greedy=True, admission="sjf"),
                       gen_lens=lens)
    assert_rollout_equal(fifo, sjf)
    np.testing.assert_array_equal(np.asarray(sjf["mask"]).sum(1), lens)
    assert s_sjf["admitted"] == s_sjf["retired"] == B


def test_sjf_aging_anti_starvation():
    """``aging=K`` bounds SJF starvation: a long request passed over K
    times jumps ahead of every shorter newcomer (starved requests drain
    in arrival order); ``aging=0`` reproduces the pure static order;
    and greedy outputs still equal the FIFO run request-for-request —
    admission order changes, per-request results do not."""
    reqs = [Request(0, 5), Request(1, 1), Request(2, 1), Request(3, 1),
            Request(4, 1)]
    q = RequestQueue(list(reqs), policy="sjf", aging=2)
    order = [q.pop(1)[0].rid for _ in range(5)]
    # rid 0 (budget 5) is skipped twice, then admitted before rids 3, 4
    assert order == [1, 2, 0, 3, 4]
    q0 = RequestQueue(list(reqs), policy="sjf", aging=0)
    assert [r.rid for r in q0.pop(5)] == [1, 2, 3, 4, 0]

    cfg = tiny_cfg()
    params = T.init_params(KEY, cfg)
    B, W = 10, 3
    lens = [N, 1, N, 2, 1, N, 2, N, 1, N]
    prompts = prompts_for(B, key=21)
    fifo, _ = serve(params, cfg, prompts, KEY,
                    GenServeConfig(wave=W, max_new_tokens=N, greedy=True),
                    gen_lens=lens)
    aged, s_aged = serve(params, cfg, prompts, KEY,
                         GenServeConfig(wave=W, max_new_tokens=N,
                                        greedy=True, admission="sjf",
                                        sjf_aging=1),
                         gen_lens=lens)
    assert_rollout_equal(fifo, aged)
    assert s_aged["admitted"] == s_aged["retired"] == B


# ---------------------------------------------------------------------------
# Chunked prefill (mixed wave-step admission)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chunk,dchunk", [(1, 1), (3, 1), (5, 1), (8, 1),
                                          (3, 3), (2, 4)])
def test_chunked_admission_single_wave_exact(setup, chunk, dchunk):
    """Tentpole pin: chunked admission on a single-wave batch is
    token-exact vs the reference path under sampling — the landing
    round's first-token draw consumes rngs[0] exactly like the one-shot
    admit, no decode key is burned during prefill rounds, and decode
    resumes at rngs[1] whatever the mixed-scan length (dchunk > 1 pins
    the multi-sub-round key bookkeeping)."""
    cfg, params = setup
    prompts = prompts_for(4)
    sampler = rollout.SamplerConfig(max_new_tokens=N, temperature=1.0,
                                    eos_token=EOS)
    ref = rollout.generate(params, cfg, prompts, jax.random.PRNGKey(7),
                           sampler)
    gcfg = GenServeConfig(wave=4, max_new_tokens=N, eos_token=EOS,
                          prefill_chunk=chunk, decode_chunk=dchunk,
                          measure_ttft=True)
    got, stats = serve(params, cfg, prompts, jax.random.PRNGKey(7), gcfg)
    assert_rollout_equal(ref, got)
    assert stats["prefill_slot_steps"] == 4 * -(-P // chunk)
    assert all(t > 0 for t in stats["ttft"].values())


def _random_trace_case(rng, case):
    """One random admission trace: mixed windows/GQA, random budgets,
    random EOS, prompts longer than the chunk."""
    window = rng.choice([None, 4])
    gqa = bool(rng.integers(0, 2))
    cfg = ModelConfig(name=f"gs-prop-{case}", n_layers=2, d_model=64,
                      n_heads=4 if gqa else 2, n_kv_heads=2,
                      head_dim=16 if gqa else 32, d_ff=128,
                      vocab_size=VOCAB_SIZE, dtype="float32",
                      pattern=(LayerSpec(window=window),))
    params = T.init_params(jax.random.PRNGKey(case), cfg)
    B = int(rng.integers(6, 12))
    W = int(rng.integers(2, 5))
    chunk = int(rng.integers(1, P))          # prompts exceed the chunk
    dchunk = int(rng.integers(1, 5))         # mixed scans span sub-rounds
    eos = int(rng.integers(0, VOCAB_SIZE)) if rng.integers(0, 2) else None
    lens = rng.integers(1, N + 1, B).tolist() if rng.integers(0, 2) \
        else None
    prompts = jax.random.randint(jax.random.PRNGKey(100 + case), (B, P),
                                 0, cfg.vocab_size, jnp.int32)
    return cfg, params, prompts, B, W, chunk, dchunk, eos, lens


@pytest.mark.parametrize("case", range(4))
def test_chunked_admission_random_traces(case):
    """Property-style pin: over random admission traces (recycling,
    ring windows, GQA, random EOS, random budgets, prompts longer than
    ``prefill_chunk``) chunked admission reproduces the one-shot admit
    path token-for-token under greedy decoding."""
    rng = np.random.default_rng(1234 + case)
    cfg, params, prompts, B, W, chunk, dchunk, eos, lens = \
        _random_trace_case(rng, case)
    kw = dict(wave=W, max_new_tokens=N, greedy=True, eos_token=eos)
    ref, s_ref = serve(params, cfg, prompts, KEY, GenServeConfig(**kw),
                       gen_lens=lens)
    got, s_got = serve(params, cfg, prompts, KEY,
                       GenServeConfig(prefill_chunk=chunk,
                                      decode_chunk=dchunk,
                                      measure_ttft=True, **kw),
                       gen_lens=lens)
    assert_rollout_equal(ref, got)
    assert s_got["admitted"] == s_got["retired"] == B
    assert s_got["prefill_slot_steps"] >= B * (P // chunk)
    # every request saw a first token
    assert len(s_got["ttft"]) == B


def test_chunked_admission_ragged_prompts(setup):
    """Per-request prompt lengths: each request's outputs equal its own
    unpadded reference rollout (per-slot landing positions)."""
    cfg, params = setup
    B = 6
    pl = [8, 3, 5, 8, 2, 6]
    prompts = np.array(prompts_for(B, key=17))
    gcfg = GenServeConfig(wave=3, max_new_tokens=N, greedy=True,
                          prefill_chunk=3)
    got, stats = serve(params, cfg, prompts, KEY, gcfg, prompt_lens=pl)
    for i, L in enumerate(pl):
        ref = rollout.generate(
            params, cfg, jnp.asarray(prompts[i:i + 1, :L]), KEY,
            rollout.SamplerConfig(max_new_tokens=N, greedy=True))
        np.testing.assert_array_equal(np.asarray(ref["gen_tokens"])[0],
                                      np.asarray(got["gen_tokens"])[i])
    # short prompts land in fewer rounds than the padded width implies
    assert stats["prefill_slot_steps"] \
        == sum(-(-l // 3) for l in pl)


def test_mixed_rounds_honest_occupancy(setup):
    """Satellite pin: prefill-only rounds are recorded as zero decode
    progress (mean_occupancy is honest), prefill work is credited in
    busy_occupancy, and the measured busy figure respects the
    prefill-aware predicted_occupancy bound."""
    cfg, params = setup
    B, W, C = 10, 4, 2
    lens = [1, 2, N, 3, N, 1, 2, N, 3, N]
    prompts = prompts_for(B, key=23)
    gcfg = GenServeConfig(wave=W, max_new_tokens=N, greedy=True,
                          prefill_chunk=C)
    got, stats = serve(params, cfg, prompts, KEY, gcfg, gen_lens=lens)
    np.testing.assert_array_equal(np.asarray(got["mask"]).sum(1), lens)
    # trace lengths agree: every mixed round contributed to both traces
    assert stats["prefill_rounds"] <= stats["decode_steps"]
    assert stats["prefill_slot_steps"] == B * -(-P // C)
    ideal = plan_mod.predicted_occupancy(
        B, wave=W, gen_lens=lens,
        prefill_rounds=plan_mod.prefill_rounds(P, C))
    assert 0 < stats["busy_occupancy"] <= ideal + 1e-9
    # prefill-only rounds drag decode occupancy below the zero-cost
    # admission ideal — the honesty the satellite fix is about
    assert stats["mean_occupancy"] < plan_mod.predicted_occupancy(
        B, wave=W, gen_lens=lens)


def test_predicted_occupancy_prefill_rounds():
    """Unit pins for the prefill-aware occupancy model."""
    # zero prefill rounds: unchanged historical behavior
    assert plan_mod.predicted_occupancy(8, wave=4) == pytest.approx(4.0)
    assert plan_mod.prefill_rounds(8, 3) == 3
    assert plan_mod.prefill_rounds(8, 0) == 0
    # uniform lens with prefill rounds need max_new_tokens
    with pytest.raises(AssertionError):
        plan_mod.predicted_occupancy(8, wave=4, prefill_rounds=2)
    # work bound: 8 requests x (4 decode + 2 prefill) rounds over 4
    # slots -> 12 rounds, occupancy 48/12
    occ = plan_mod.predicted_occupancy(8, wave=4, prefill_rounds=2,
                                       max_new_tokens=4)
    assert occ == pytest.approx(48 / 12)
    # chain bound: one long request dominates
    occ = plan_mod.predicted_occupancy(2, wave=4, gen_lens=[10, 1],
                                       prefill_rounds=3)
    assert occ == pytest.approx((10 + 1 + 6) / 13)
    # per-request prefill rounds: the chain bound must track the worst
    # (len + rounds) pair, not the mean — a short-prompt long-gen
    # request finishing in 11 rounds yields busy 17/11, and the bound
    # covers it (the scalar-mean form would not)
    occ = plan_mod.predicted_occupancy(2, wave=4, gen_lens=[10, 1],
                                       prefill_rounds=[1, 5])
    assert occ == pytest.approx(17 / 11)


def test_costmodel_gen_prefill_chunk():
    """The mixed-round prefill price is positive for GEN, zero for other
    tasks, and scales with the chunk width."""
    from repro.core.costmodel import CostModel
    from repro.core import topology, workflow
    from repro.core.enumerate import build_plan
    topo = topology.build_host(2)
    wf = workflow.make_grpo(workflow.QWEN_1_7B, global_batch=64)
    plan = build_plan(topo, wf, (tuple(range(wf.n_tasks)),), [2], [0, 1])
    cm = CostModel(topo, wf)
    gen_t = 0
    c16 = cm.gen_prefill_chunk(plan, gen_t, chunk=16)
    c64 = cm.gen_prefill_chunk(plan, gen_t, chunk=64)
    assert 0 < c16 < c64
    train_t = wf.n_tasks - 1
    assert cm.gen_prefill_chunk(plan, train_t, chunk=16) == 0.0


def test_cache_gather_scatter_roundtrip():
    """[R, B, ...] cache rows move wholesale: scatter(src at mask) then
    gather returns src rows exactly; unmasked rows untouched."""
    from repro.models import cache as cache_mod
    rng = np.random.default_rng(0)
    blocks = {"layer0": {"k": jnp.asarray(rng.normal(size=(2, 4, 3, 2, 5)),
                                          jnp.float32),
                         "conv": jnp.asarray(rng.normal(size=(2, 4, 7)),
                                             jnp.float32)}}
    src = jax.tree_util.tree_map(lambda l: l + 100.0, blocks)
    mask = jnp.asarray([True, False, True, False])
    out = cache_mod.scatter_slots(blocks, src, mask)
    got = cache_mod.gather_slots(out, jnp.asarray([0, 2]))
    want = cache_mod.gather_slots(src, jnp.asarray([0, 2]))
    kept = cache_mod.gather_slots(out, jnp.asarray([1, 3]))
    orig = cache_mod.gather_slots(blocks, jnp.asarray([1, 3]))
    for a, b in ((got, want), (kept, orig)):
        for la, lb in zip(jax.tree_util.tree_leaves(a),
                          jax.tree_util.tree_leaves(b)):
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_scheduler_slot_table_invariants():
    table = SlotTable(3)
    q = RequestQueue([Request(i, 4) for i in range(5)])
    reqs = q.pop(len(table.free_slots()))
    table.admit(table.free_slots(), reqs)
    assert table.active == 3 and len(q) == 2
    with pytest.raises(AssertionError):
        table.admit([0], q.pop(1))           # slot already occupied
    done = table.retire_finished(np.array([True, False, True]))
    assert done == [1] and table.active == 2
    assert table.slot_req[1] == FREE
    table.record_step([3, 2, 2])
    assert table.decode_steps == 3 and table.slot_steps == 7
    assert table.mean_occupancy() == pytest.approx(7 / 3)


def test_adapter_fast_path_stats(setup):
    cfg, params = setup
    prompts = prompts_for(4)
    sampler = rollout.SamplerConfig(max_new_tokens=N, eos_token=EOS)
    ro, stats = genserve.generate(params, cfg, prompts,
                                  jax.random.PRNGKey(7), sampler, wave=8)
    assert stats["engine"] == "single-wave"
    assert stats["decode_steps"] == N
    assert stats["slot_steps"] == int(np.asarray(ro["mask"]).sum())
    ref = rollout.generate(params, cfg, prompts, jax.random.PRNGKey(7),
                           sampler)
    assert_rollout_equal(ref, ro)


def test_engine_gen_executor_chunked_prefill_parity():
    """TaskKind.GEN with chunked admission: the engine's measured-vs-
    predicted occupancy covers prefill rounds (busy accounting on the
    measured side, prefill_rounds on the prediction side) instead of
    assuming admission free."""
    cfg = tiny_cfg()
    task = AdditionTask(max_operand=9)
    rl = RLConfig(algorithm="grpo", n_rollouts=4, max_new_tokens=4,
                  gen_engine="genserve", decode_chunk=2, prefill_chunk=2)
    trainer = RLTrainer(cfg, rl, task, KEY)
    rng = np.random.default_rng(0)
    prompts, answers = task.sample_batch(rng, 3)
    m = trainer.iteration(prompts, answers, jax.random.PRNGKey(7))
    assert m["gen_prefill_rounds"] >= 1
    assert 0 < m["gen_busy_occupancy"] <= m["gen_wave"]
    summary = trainer.engine.wave_occupancy_summary()
    assert summary["measured_occupancy"] > 0
    assert summary["predicted_occupancy"] > 0
    # prediction charges admission: never above the free-admission ideal
    # (equal exactly when batch == wave — every slot busy throughout)
    free = plan_mod.predicted_occupancy(12, wave=m["gen_wave"])
    assert summary["predicted_occupancy"] <= free
    assert np.isfinite(summary["ratio"])


def test_engine_gen_executor_emits_wave_events():
    """TaskKind.GEN through genserve: per-wave Event entries with
    occupancy annotations, comparable against decode_wave predictions."""
    cfg = tiny_cfg()
    task = AdditionTask(max_operand=9)
    rl = RLConfig(algorithm="grpo", n_rollouts=4, max_new_tokens=4,
                  gen_engine="genserve", decode_chunk=2)
    trainer = RLTrainer(cfg, rl, task, KEY)
    rng = np.random.default_rng(0)
    key = jax.random.PRNGKey(7)
    for _ in range(2):
        prompts, answers = task.sample_batch(rng, 3)
        key, k = jax.random.split(key)
        m = trainer.iteration(prompts, answers, k)
    assert m["gen_wave"] >= 1
    assert 0 < m["gen_wave_occupancy"] <= m["gen_wave"]
    events = trainer.engine.wave_timeline
    assert events and all(e.occupancy is not None and e.wave is not None
                          for e in events)
    assert {e.kind for e in events} == {"start", "end"}
    assert {e.iteration for e in events} == {0, 1}
    summary = trainer.engine.wave_occupancy_summary()
    assert summary["measured_occupancy"] > 0
    assert summary["predicted_occupancy"] > 0
    assert np.isfinite(summary["ratio"])
