"""RL math + end-to-end iteration tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.synthetic import AdditionTask, VOCAB_SIZE, EOS, PAD
from repro.models.config import ModelConfig
from repro.rl import gae, losses, rollout
from repro.rl.trainer import RLConfig, RLTrainer

KEY = jax.random.PRNGKey(0)


def naive_gae(rewards, values, mask, gamma, lam):
    B, T = rewards.shape
    values_next = np.concatenate([values[:, 1:], np.zeros((B, 1))], axis=1)
    deltas = rewards + gamma * values_next * mask - values
    adv = np.zeros_like(rewards)
    for b in range(B):
        run = 0.0
        for t in reversed(range(T)):
            run = deltas[b, t] + gamma * lam * mask[b, t] * run
            adv[b, t] = run
    return adv * mask


def test_gae_matches_naive():
    rng = np.random.default_rng(0)
    B, T = 4, 12
    rewards = rng.normal(size=(B, T)).astype(np.float32)
    values = rng.normal(size=(B, T)).astype(np.float32)
    mask = (rng.random((B, T)) > 0.2).astype(np.float32)
    adv, ret = gae.gae_advantages(jnp.asarray(rewards), jnp.asarray(values),
                                  jnp.asarray(mask), gamma=0.97, lam=0.9)
    expected = naive_gae(rewards, values, mask, 0.97, 0.9)
    np.testing.assert_allclose(np.asarray(adv), expected, rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(ret),
                               expected + values * mask, rtol=1e-5,
                               atol=1e-5)


def test_grpo_advantages_zero_mean_per_group():
    rng = np.random.default_rng(1)
    B, G, T = 12, 4, 6
    rewards = jnp.asarray(rng.normal(size=(B,)).astype(np.float32))
    mask = jnp.ones((B, T), jnp.float32)
    adv = gae.grpo_advantages(rewards, G, mask)
    per_group = np.asarray(adv)[:, 0].reshape(B // G, G)
    np.testing.assert_allclose(per_group.mean(axis=1), 0.0, atol=1e-5)


def test_ppo_loss_at_ratio_one():
    B, T = 3, 5
    logp = jnp.zeros((B, T))
    adv = jnp.asarray(np.random.default_rng(2).normal(size=(B, T)),
                      jnp.float32)
    mask = jnp.ones((B, T))
    out = losses.ppo_policy_loss(logp, logp, adv, mask)
    np.testing.assert_allclose(float(out["loss"]), float(-adv.mean()),
                               rtol=1e-6)
    assert float(out["clip_frac"]) == 0.0


def test_kl_penalised_rewards_places_score_at_last_token():
    B, T = 2, 6
    score = jnp.asarray([1.0, 2.0])
    lp = jnp.zeros((B, T))
    mask = jnp.asarray([[1, 1, 1, 0, 0, 0], [1, 1, 1, 1, 1, 1]],
                       jnp.float32)
    rewards, kl = losses.kl_penalised_rewards(score, lp, lp, mask)
    assert float(rewards[0, 2]) == 1.0
    assert float(rewards[1, 5]) == 2.0
    assert float(kl) == 0.0


def tiny_cfg():
    return ModelConfig(name="tiny", n_layers=2, d_model=64, n_heads=2,
                       n_kv_heads=2, head_dim=32, d_ff=128,
                       vocab_size=VOCAB_SIZE, dtype="float32")


def test_rollout_logprobs_consistent_with_teacher_forcing():
    from repro.models import transformer as T
    cfg = tiny_cfg()
    params = T.init_params(KEY, cfg)
    prompts = jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size, jnp.int32)
    sampler = rollout.SamplerConfig(max_new_tokens=5, greedy=True)
    ro = rollout.generate(params, cfg, prompts, KEY, sampler)
    lp_tf, _ = rollout.sequence_logprobs(params, cfg, ro["sequences"],
                                         gen_start=prompts.shape[1])
    np.testing.assert_allclose(np.asarray(ro["logprobs"]),
                               np.asarray(lp_tf), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("algorithm", ["grpo", "ppo"])
def test_rl_iteration_runs(algorithm):
    cfg = tiny_cfg()
    task = AdditionTask(max_operand=9)
    rl = RLConfig(algorithm=algorithm, n_rollouts=4, max_new_tokens=4)
    trainer = RLTrainer(cfg, rl, task, KEY)
    rng = np.random.default_rng(0)
    prompts, answers = task.sample_batch(rng, 4)
    m = trainer.iteration(prompts, answers, jax.random.PRNGKey(1))
    for k, v in m.items():
        assert np.isfinite(v), f"{k} not finite"
    assert 0.0 <= m["reward_mean"] <= 1.0


def test_grpo_learns_single_digit_addition():
    """A few iterations must visibly increase the reward."""
    cfg = ModelConfig(name="tiny2", n_layers=2, d_model=96, n_heads=4,
                      n_kv_heads=2, head_dim=24, d_ff=192,
                      vocab_size=VOCAB_SIZE, dtype="float32")
    task = AdditionTask(max_operand=4)
    rl = RLConfig(algorithm="grpo", n_rollouts=8, max_new_tokens=3,
                  lr=5e-4, kl_beta=0.0)
    trainer = RLTrainer(cfg, rl, task, KEY)
    rng = np.random.default_rng(3)
    key = jax.random.PRNGKey(9)
    rewards = []
    for it in range(12):
        prompts, answers = task.sample_batch(rng, 12)
        key, k = jax.random.split(key)
        m = trainer.iteration(prompts, answers, k)
        rewards.append(m["reward_mean"])
    assert np.mean(rewards[-3:]) > np.mean(rewards[:3]) + 0.05


def test_reward_partial_credit():
    task = AdditionTask(max_operand=99)
    assert task.reward(12, np.array([1, 2, EOS])) == 1.0
    assert 0 < task.reward(12, np.array([1, 3, EOS])) < 1.0
    assert task.reward(12, np.array([PAD, PAD, PAD])) == 0.0
