"""Per-architecture smoke tests: reduced variant (<=2 layers, d_model<=512,
<=4 experts) instantiates and runs one forward + one train step on CPU,
asserting output shapes and finiteness. Decode parity for causal archs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import archs
from repro.configs.shapes import token_splits
from repro.launch import steps as steps_mod
from repro.models import transformer as T
from repro.optim import adam

ALL_ARCHS = sorted(archs.ARCHS)
KEY = jax.random.PRNGKey(0)


def smoke_inputs(cfg, batch=2, seq=32):
    n_feat, n_tok = token_splits(cfg, seq)
    n_feat = min(n_feat, seq // 2) if n_feat else 0
    n_tok = seq - n_feat
    out = {}
    if n_feat:
        out["features"] = jax.random.normal(
            KEY, (batch, n_feat, cfg.feature_dim), jnp.dtype(cfg.dtype))
    if n_tok:
        out["tokens"] = jax.random.randint(KEY, (batch, n_tok), 0,
                                           cfg.vocab_size, jnp.int32)
    return out


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_reduced_config_limits(name):
    cfg = archs.get(name, smoke=True)
    cfg.validate()
    assert cfg.n_layers <= 2
    assert cfg.d_model <= 512
    if cfg.has_moe:
        assert cfg.n_experts <= 4


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_forward_shapes_and_finite(name):
    cfg = archs.get(name, smoke=True)
    params = T.init_params(KEY, cfg)
    B, S = 2, 32
    out = T.forward(params, cfg, smoke_inputs(cfg, B, S))
    assert out["logits"].shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(out["logits"]).all())
    assert bool(jnp.isfinite(out["aux_loss"]))
    # parameter count within 5% of the analytic config estimate
    actual = T.count_params(params)
    assert abs(actual - cfg.param_count()) / actual < 0.05


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_one_train_step(name):
    cfg = archs.get(name, smoke=True)
    params = T.init_params(KEY, cfg)
    opt_cfg = adam.AdamConfig(lr=1e-3)
    opt_state = adam.init_adam_state(params, opt_cfg)
    B, S = 2, 32
    batch = smoke_inputs(cfg, B, S)
    batch["labels"] = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size,
                                         jnp.int32)
    batch["loss_mask"] = jnp.ones((B, S), jnp.dtype(cfg.dtype))
    step = jax.jit(steps_mod.make_train_step(cfg, opt_cfg))
    new_params, new_opt, metrics = step(params, opt_state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # parameters actually changed
    diff = sum(float(jnp.abs(a - b).sum()) for a, b in zip(
        jax.tree_util.tree_leaves(params),
        jax.tree_util.tree_leaves(new_params)))
    assert diff > 0


DECODE_ARCHS = [n for n in ALL_ARCHS
                if not archs.get(n, smoke=True).is_encoder_only
                and archs.get(n, smoke=True).frontend == "tokens"]


@pytest.mark.parametrize("name", DECODE_ARCHS)
def test_decode_matches_forward(name):
    cfg = archs.get(name, smoke=True)
    if cfg.has_moe:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)  # avoid drops
    params = T.init_params(KEY, cfg)
    B, S, Sp = 2, 24, 20
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size, jnp.int32)
    full = T.forward(params, cfg, {"tokens": toks}, remat=False)["logits"]
    out = T.forward(params, cfg, {"tokens": toks[:, :Sp]},
                    return_cache=True, max_cache_len=S, remat=False)
    cache = out["cache"]
    for t in range(Sp, S):
        logits, cache = T.decode_step(params, cfg, toks[:, t:t + 1], cache)
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(full[:, t]),
                                   rtol=2e-3, atol=2e-3)
