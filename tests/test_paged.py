"""Paged KV cache + radix prefix reuse (genserve perf-opt layer).

- paged no-sharing exactness vs the contiguous chunked engine across
  the PR-5 admission matrix — ring windows, GQA, recycled slots,
  prompts longer than the chunk, ragged prompt_lens — under sampling
  (the identity block table must be invisible);
- single-wave paged batches token-exact vs ``rl.rollout.generate``
  (the acceptance pin: sharing disabled, reference path reproduced);
- prefix sharing under greedy decoding: token-exact vs the contiguous
  run, deterministic skipped-token counts, copy-on-write on a
  divergent partial page;
- host allocator: PagePool/RadixCache refcount + free-list invariants
  under a randomized admit/insert/evict/retire exerciser;
- device indirection units: identity view == gather view, copy_pages
  sentinel semantics, zero_paged_slots leaves the pool untouched,
  supports_prefix_sharing predicate;
- cost-model pricing with an expected prefix-hit rate
  (``prefill_rounds`` / ``predicted_occupancy`` / ``gen_prefill_chunk``).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import plan as plan_mod
from repro.data.synthetic import EOS, VOCAB_SIZE
from repro.genserve.decoder import GenServeConfig, serve
from repro.genserve.pagepool import PagePool, RadixCache
from repro.models import cache as cache_mod
from repro.models import transformer as T
from repro.models.config import LayerSpec, ModelConfig

KEY = jax.random.PRNGKey(0)
P, N = 8, 6


def paged_cfg(window=None, n_kv_heads=2):
    return ModelConfig(name=f"pg-w{window}-kv{n_kv_heads}", n_layers=2,
                       d_model=64, n_heads=2, n_kv_heads=n_kv_heads,
                       head_dim=32, d_ff=128, vocab_size=VOCAB_SIZE,
                       dtype="float32", pattern=(LayerSpec(window=window),))


def prompts_for(n, key=3, cfg=None):
    return jax.random.randint(jax.random.PRNGKey(key), (n, P), 0,
                              (cfg or paged_cfg()).vocab_size, jnp.int32)


def assert_rollout_equal(ref, got, atol=1e-4):
    mr, mg = np.asarray(ref["mask"]), np.asarray(got["mask"])
    np.testing.assert_array_equal(mr, mg)
    np.testing.assert_array_equal(
        np.asarray(ref["gen_tokens"]) * mr.astype(np.int32),
        np.asarray(got["gen_tokens"]) * mg.astype(np.int32))
    np.testing.assert_allclose(np.asarray(ref["logprobs"]) * mr,
                               np.asarray(got["logprobs"]) * mg,
                               rtol=1e-4, atol=atol)
    np.testing.assert_array_equal(np.asarray(ref["sequences"])[:, :P],
                                  np.asarray(got["sequences"])[:, :P])


# ---------------------------------------------------------------------------
# Paged (identity block table) == contiguous, across the admission matrix
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("window,kv,ps", [
    (None, 2, 4),     # full attention
    (6, 2, 4),        # ring window (< max_seq: wraps mid-run)
    (None, 1, 2),     # GQA, page smaller than any prompt
    (6, 1, 3),        # ring + GQA + page not dividing the window
])
def test_paged_noshare_matrix_exact(window, kv, ps):
    """The paged cache behind an identity block table is token-exact vs
    the contiguous chunked engine under sampling: recycled slots
    (B > W), prompts longer than the chunk, ragged prompt_lens, EOS
    retirement — the indirection must be invisible."""
    cfg = paged_cfg(window, kv)
    params = T.init_params(KEY, cfg)
    B, W, C = 10, 3, 3
    prompts = prompts_for(B, key=11, cfg=cfg)
    plens = [8, 5, 3, 8, 4, 8, 6, 3, 8, 5]
    lens = [N, 1, N, 2, 1, N, 2, N, 1, N]
    kw = dict(wave=W, max_new_tokens=N, eos_token=EOS, prefill_chunk=C,
              temperature=1.0, greedy=False)
    ref, _ = serve(params, cfg, prompts, jax.random.PRNGKey(7),
                   GenServeConfig(**kw), gen_lens=lens, prompt_lens=plens)
    got, stats = serve(params, cfg, prompts, jax.random.PRNGKey(7),
                       GenServeConfig(**kw, page_size=ps),
                       gen_lens=lens, prompt_lens=plens)
    assert_rollout_equal(ref, got)
    assert stats["page_size"] == ps and not stats["prefix_cache"]
    assert stats["prefix_hit_rate"] == 0.0


def test_paged_single_wave_exact_vs_rollout():
    """Acceptance pin: a single-wave paged batch with sharing disabled
    reproduces ``rl.rollout.generate`` token-for-token under sampling."""
    from repro.rl import rollout
    cfg = paged_cfg()
    params = T.init_params(KEY, cfg)
    prompts = prompts_for(4)
    sampler = rollout.SamplerConfig(max_new_tokens=N, temperature=1.0,
                                    eos_token=EOS)
    ref = rollout.generate(params, cfg, prompts, jax.random.PRNGKey(7),
                           sampler)
    got, stats = serve(params, cfg, prompts, jax.random.PRNGKey(7),
                       GenServeConfig(wave=4, max_new_tokens=N,
                                      eos_token=EOS, prefill_chunk=3,
                                      temperature=1.0, greedy=False,
                                      page_size=4))
    assert_rollout_equal(ref, got)
    assert stats["admitted"] == stats["retired"] == 4


# ---------------------------------------------------------------------------
# Prefix sharing (greedy): exactness, hit accounting, copy-on-write
# ---------------------------------------------------------------------------

def test_prefix_sharing_greedy_exact_and_hits():
    """Under greedy decoding prefix sharing is token-exact vs the
    contiguous run (skipped prefill shifts landing rounds, which only
    matters for sampled rng consumption): staggered re-admissions of
    two hot prompts hit everything but the capped last token."""
    cfg = paged_cfg()
    params = T.init_params(KEY, cfg)
    B, W, C, ps = 10, 2, 4, 2
    base = prompts_for(2, key=5, cfg=cfg)
    prompts = jnp.asarray(np.asarray(base)[np.arange(B) % 2])
    lens = [3, 2, 4, 3, 2, 3, 4, 2, 3, 3]
    kw = dict(wave=W, max_new_tokens=N, prefill_chunk=C, greedy=True)
    ref, _ = serve(params, cfg, prompts, KEY, GenServeConfig(**kw),
                   gen_lens=lens)
    got, stats = serve(params, cfg, prompts, KEY,
                       GenServeConfig(**kw, page_size=ps,
                                      prefix_cache=True),
                       gen_lens=lens)
    assert_rollout_equal(ref, got)
    # wave 0 admits both hot prompts (miss: pages publish at landing);
    # the 8 re-admissions each hit P-1 = 7 tokens (3 full pages + a
    # 1-token partial overlap, capped so the landing chunk still runs)
    assert stats["prefill_tokens_skipped"] == 8 * (P - 1)
    assert stats["prefix_hit_rate"] == pytest.approx(8 * 7 / (10 * 8))
    stats["_pagepool"].check()


def test_prefix_sharing_cow_divergent_page():
    """A prompt diverging inside the last matched partial page triggers
    copy-on-write: the shared page is copied before the divergent
    suffix is written, so the donor's cache (and output) is untouched
    and both runs stay exact vs contiguous."""
    cfg = paged_cfg()
    params = T.init_params(KEY, cfg)
    ps, C, W = 4, 4, 2
    rng = np.random.default_rng(9)
    base = rng.integers(0, cfg.vocab_size, P)
    other = rng.integers(0, cfg.vocab_size, P)
    div = base.copy()
    div[-1] = (div[-1] + 1) % cfg.vocab_size     # diverge in page 1
    prompts = jnp.asarray(np.stack([base, other, div, base]), jnp.int32)
    lens = [2, 6, 3, 3]
    kw = dict(wave=W, max_new_tokens=N, prefill_chunk=C, greedy=True)
    ref, _ = serve(params, cfg, prompts, KEY, GenServeConfig(**kw),
                   gen_lens=lens)
    got, stats = serve(params, cfg, prompts, KEY,
                       GenServeConfig(**kw, page_size=ps,
                                      prefix_cache=True),
                       gen_lens=lens)
    assert_rollout_equal(ref, got)
    # r2 (divergent) and r3 (identical) each hit 1 full page + a
    # 3-token partial: 7 tokens apiece; r0/r1 miss (first wave)
    assert stats["prefill_tokens_skipped"] == 2 * 7
    stats["_pagepool"].check()


# ---------------------------------------------------------------------------
# Host allocator invariants
# ---------------------------------------------------------------------------

def test_pagepool_radix_random_invariants():
    """Randomized admit/insert/evict/retire against the decoder's own
    allocation discipline: refcount/free-list invariants hold at every
    step, eviction can always make room (pool = 2*W*MP), and a full
    drain + evict returns every page to the free list."""
    ps, MP, W = 2, 4, 2
    NP = 2 * W * MP
    pool = PagePool(NP, ps)
    radix = RadixCache(pool)
    rng = np.random.default_rng(0)
    live = {}
    for _ in range(300):
        if len(live) < W and (not live or rng.random() < 0.6):
            toks = rng.integers(0, 3, P).tolist()    # small alphabet:
            full, part = radix.match(toks, len(toks) - 1)   # real hits
            pool.incref(full)
            cow = []
            if part is not None:
                pool.incref([part[0]])
                cow = [part[0]]
            need = MP - len(full)
            if pool.available() < need:
                radix.evict(need - pool.available())
            fresh = pool.alloc(need)
            assert fresh is not None, "2*W*MP pool must always admit"
            pool.decref(cow)
            row = full + fresh
            slot = min(set(range(W)) - set(live))
            live[slot] = row
            radix.insert(toks, row[:len(toks) // ps])
        else:
            pool.decref(live.pop(int(rng.choice(sorted(live)))))
        pool.check()
        assert all(rc <= W + 1 for rc in pool.refcount)
    for row in live.values():
        pool.decref(row)
    pool.check()
    radix.evict(NP)
    assert pool.available() == NP
    pool.check()


# ---------------------------------------------------------------------------
# Device-side indirection units
# ---------------------------------------------------------------------------

def _mixed_cfg():
    return ModelConfig(name="pg-mixed", n_layers=2, d_model=64, n_heads=2,
                       n_kv_heads=2, head_dim=32, d_ff=128,
                       vocab_size=VOCAB_SIZE, dtype="float32",
                       pattern=(LayerSpec(), LayerSpec(window=6)))


def _random_paged(cfg, W, max_seq, ps, seed=0):
    blocks = cache_mod.init_paged_cache(cfg, W, max_seq, page_size=ps,
                                        dtype=jnp.float32)["blocks"]
    rng = np.random.default_rng(seed)
    return jax.tree_util.tree_map(
        lambda l: jnp.asarray(rng.standard_normal(l.shape), l.dtype),
        blocks)


def test_identity_view_matches_gather_view():
    """The static identity fast path (reshape) must equal the general
    gather through an identity block table — including windowed layers
    whose per-layer page count is below the global max."""
    cfg = _mixed_cfg()
    W, max_seq, ps = 3, 14, 4
    blocks = _random_paged(cfg, W, max_seq, ps)
    MP = cache_mod.max_pages_per_slot(cfg, max_seq, ps)
    btab = jnp.asarray(cache_mod.identity_block_table(W, MP))
    a = cache_mod.paged_view(cfg, blocks, btab, max_seq, page_size=ps)
    b = cache_mod.paged_view(cfg, blocks, btab, max_seq, page_size=ps,
                             identity=True)
    jax.tree_util.tree_map(np.testing.assert_array_equal, a, b)


def test_copy_pages_sentinel_semantics():
    """copy_pages: a sentinel source writes zeros, a sentinel
    destination is dropped, real pairs copy exactly."""
    cfg = paged_cfg()
    W, max_seq, ps = 2, 14, 4
    blocks = _random_paged(cfg, W, max_seq, ps)
    NP = blocks["layer0"]["k"].shape[1]
    src = jnp.asarray([0, NP, 1], jnp.int32)
    dst = jnp.asarray([2, 3, NP], jnp.int32)
    out = cache_mod.copy_pages(cfg, blocks, src, dst)
    for name in blocks:
        for leaf in ("k", "v"):
            old = np.asarray(blocks[name][leaf])
            new = np.asarray(out[name][leaf])
            np.testing.assert_array_equal(new[:, 2], old[:, 0])
            np.testing.assert_array_equal(new[:, 3], np.zeros_like(old[:, 3]))
            keep = [i for i in range(NP) if i not in (2, 3)]
            np.testing.assert_array_equal(new[:, keep], old[:, keep])


def test_zero_paged_slots_leaves_pool_untouched():
    """Zeroing a recycled slot must not clobber the pool — a freed
    slot's pages may be shared with (or reallocated to) other slots;
    validity masks make the stale content unobservable."""
    cfg = paged_cfg()
    blocks = _random_paged(cfg, 2, 14, 4)
    out = cache_mod.zero_paged_slots(cfg, blocks,
                                     jnp.asarray([True, False]))
    for name in blocks:
        for leaf in ("k", "v"):
            np.testing.assert_array_equal(np.asarray(out[name][leaf]),
                                          np.asarray(blocks[name][leaf]))


def test_supports_prefix_sharing_predicate():
    assert cache_mod.supports_prefix_sharing(paged_cfg())
    assert not cache_mod.supports_prefix_sharing(paged_cfg(window=6))


# ---------------------------------------------------------------------------
# Cost-model pricing with an expected prefix-hit rate
# ---------------------------------------------------------------------------

def test_prefill_rounds_prefix_hit_rate():
    assert plan_mod.prefill_rounds(256, 32) == 8
    assert plan_mod.prefill_rounds(256, 32, prefix_hit_rate=0.75) == 2
    # the landing chunk always runs, even on a full hit
    assert plan_mod.prefill_rounds(256, 32, prefix_hit_rate=1.0) == 1
    assert plan_mod.prefill_rounds(256, 0, prefix_hit_rate=0.9) == 0


def test_predicted_occupancy_prefix_hit_rate():
    # gen_lens=[10, 1], prefill_rounds=[1, 5]: busy 17 over an 11-round
    # chain (pinned by test_genserve).  An 80% hit rate shrinks the
    # per-request rounds to max(0.2*c, 1) -> [1, 1]: busy 13, same chain
    hot = plan_mod.predicted_occupancy(2, wave=4, gen_lens=[10, 1],
                                       prefill_rounds=[1, 5],
                                       prefix_hit_rate=0.8)
    assert hot == pytest.approx(13 / 11)
    # one-shot admission (no rounds) is untouched by the hit rate
    assert plan_mod.predicted_occupancy(
        2, wave=4, gen_lens=[10, 1], prefix_hit_rate=0.9) == \
        plan_mod.predicted_occupancy(2, wave=4, gen_lens=[10, 1])


def test_gen_prefill_chunk_prefix_hit_rate():
    """The mixed-round prefill price scales by the uncached fraction."""
    from repro.core.costmodel import CostModel
    from repro.core import topology, workflow
    from repro.core.enumerate import build_plan
    topo = topology.build_host(2)
    wf = workflow.make_grpo(workflow.QWEN_1_7B, global_batch=64)
    plan = build_plan(topo, wf, (tuple(range(wf.n_tasks)),), [2], [0, 1])
    cm = CostModel(topo, wf)
    c = cm.gen_prefill_chunk(plan, 0, chunk=32)
    assert c > 0
    assert cm.gen_prefill_chunk(plan, 0, chunk=32, prefix_hit_rate=0.5) \
        == pytest.approx(0.5 * c)
    assert cm.gen_prefill_chunk(plan, 0, chunk=32, prefix_hit_rate=1.0) \
        == 0.0
