"""Launch-layer tests: host-mesh training, sharding specs, input specs,
skip rules, HLO analyzer (on a small local program)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import archs
from repro.configs.shapes import SHAPES, input_specs, skip_reason
from repro.launch import hlo_analysis, steps as steps_mod
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T
from repro.optim import adam
from repro.parallel import sharding as sh


def test_host_mesh_train_step_runs():
    cfg = archs.get("qwen3-0.6b", smoke=True)
    mesh = make_host_mesh()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    opt_cfg = adam.AdamConfig()
    opt = adam.init_adam_state(params, opt_cfg)
    step = jax.jit(steps_mod.make_train_step(cfg, opt_cfg))
    B, S = 2, 32
    batch = {
        "tokens": jnp.zeros((B, S), jnp.int32),
        "labels": jnp.zeros((B, S), jnp.int32),
        "loss_mask": jnp.ones((B, S), jnp.float32),
    }
    with mesh:
        _, _, m = step(params, opt, batch)
    assert bool(jnp.isfinite(m["loss"]))


def test_param_specs_cover_all_leaves():
    cfg = archs.get("jamba-1.5-large-398b", smoke=True)
    params = jax.eval_shape(
        lambda: T.init_params(jax.random.PRNGKey(0), cfg))
    specs = sh.param_tree_specs(params)
    n_params = len(jax.tree_util.tree_leaves(params))
    n_specs = len(jax.tree_util.tree_leaves(
        specs, is_leaf=lambda s: isinstance(s, P)))
    assert n_params == n_specs
    # blocks leaves carry the leading stacked dim as None
    flat = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda s: isinstance(s, P))[0]
    for path, spec in flat:
        if sh.path_str(path).startswith("blocks/"):
            assert list(spec)[0] is None


def test_skip_rules():
    rules = {
        ("hubert-xlarge", "decode_32k"): True,
        ("hubert-xlarge", "long_500k"): True,
        ("phi3-medium-14b", "long_500k"): True,
        ("pixtral-12b", "long_500k"): True,
        ("qwen3-0.6b", "long_500k"): True,
        ("nemotron-4-15b", "long_500k"): True,
        ("granite-moe-3b-a800m", "long_500k"): True,
        ("mixtral-8x7b", "long_500k"): False,   # native SWA
        ("gemma2-27b", "long_500k"): False,     # long-mode window
        ("rwkv6-3b", "long_500k"): False,
        ("jamba-1.5-large-398b", "long_500k"): False,
        ("phi3-medium-14b", "train_4k"): False,
    }
    for (arch, shape), should_skip in rules.items():
        reason = skip_reason(archs.get(arch), SHAPES[shape])
        assert (reason is not None) == should_skip, \
            f"{arch}/{shape}: {reason}"
    # total runnable pairs: 33 of 40
    runnable = sum(1 for a in archs.ARCHS for s in SHAPES
                   if skip_reason(archs.get(a), SHAPES[s]) is None)
    assert runnable == 33


def test_input_specs_match_real_batches():
    """ShapeDtypeStructs must be consumable by the real step functions
    (verified structurally on the smoke config)."""
    cfg = archs.get("mixtral-8x7b")
    specs = input_specs(cfg, SHAPES["train_4k"])
    assert specs["tokens"].shape == (256, 4096)
    assert specs["labels"].shape == (256, 4096)
    dspecs = input_specs(cfg, SHAPES["decode_32k"])
    assert dspecs["tokens"].shape == (128, 1)
    kv = dspecs["cache"]["blocks"]["layer0"]["k"]
    # mixtral SWA: ring cache bounded by the 4096 window
    assert kv.shape[2] == 4096
    long = input_specs(cfg, SHAPES["long_500k"])
    assert long["cache"]["blocks"]["layer0"]["k"].shape[2] == 4096


def test_sanitize_spec_examples():
    class FakeMesh:
        axis_names = ("data", "model")

        class devices:
            shape = (16, 16)
    # 40 heads not divisible by 16 -> dropped
    assert sh.sanitize_spec(P(None, "model"), (10, 40), FakeMesh) \
        == P(None, None)
    assert sh.sanitize_spec(P("data", "model"), (64, 32), FakeMesh) \
        == P("data", "model")
    assert sh.sanitize_spec(P(("data", "model"),), (64,), FakeMesh) \
        == P("data")


def test_hlo_analysis_counts_loops():
    """A scanned matmul must count trip_count * per-iteration flops."""
    R, M = 7, 64

    def f(x, w):
        def body(h, wi):
            return h @ wi, None
        h, _ = jax.lax.scan(body, x, w)
        return h

    x = jnp.ones((M, M))
    w = jnp.ones((R, M, M))
    compiled = jax.jit(f).lower(x, w).compile()
    cost = hlo_analysis.analyze(compiled.as_text())
    expected = 2 * M * M * M * R
    assert expected * 0.9 <= cost.flops <= expected * 1.6, \
        f"flops={cost.flops:.3e} expected~{expected:.3e}"


def test_roofline_terms():
    from repro.launch.roofline import roofline_terms
    rec = {
        "n_devices": 256, "phase": "train", "seq_len": 4096,
        "global_batch": 256, "active_params": int(1e9),
        "flops_per_device": 1e13, "bytes_per_device": 1e11,
        "collective_bytes_per_device": {"all-reduce": 5e9},
    }
    t = roofline_terms(rec)
    assert t["dominant"] in ("compute", "memory", "collective")
    assert t["compute_s"] > 0 and t["memory_s"] > 0
    assert 0 < t["useful_ratio"] < 10


def test_hlo_profile_and_slice_accounting():
    """hlo_profile attributes loop-aware contributions; fused
    dynamic-slice params charge slice bytes, not the full operand."""
    import jax
    import jax.numpy as jnp
    from repro.launch.hlo_profile import op_contributions

    R, M = 64, 32

    def f(x, w):
        def body(h, wi):
            return jnp.tanh(h @ wi), None
        h, _ = jax.lax.scan(body, x, w)
        return h

    x = jnp.ones((M, M))
    w = jnp.ones((R, M, M))
    hlo = jax.jit(f).lower(x, w).compile().as_text()
    rows = op_contributions(hlo)
    flops = sum(r[0] for r in rows)
    expected = (2 * M ** 3 + M * M) * R
    assert expected * 0.9 <= flops <= expected * 1.8
    # bytes must NOT scale as (full stacked weight) x (iterations): the
    # fused dynamic-slice rule charges only the per-iteration slice
    total_bytes = sum(r[1] for r in rows)
    tile = M * M * 4
    honest_per_iter = 12 * tile          # h in/out + w slice + chain slack
    overcount = R * R * tile             # full stack read per iteration
    assert total_bytes < min(R * honest_per_iter, overcount // 2)


def test_loadbalance_guard_never_regresses():
    from repro.core import enumerate as enum_mod, loadbalance, topology, \
        workflow
    from repro.core.costmodel import CostModel
    topo = topology.build_testbed("multi_continent")
    wf = workflow.make_ppo(workflow.QWEN_4B)
    grouping = (tuple(range(wf.n_tasks)),)
    plan = enum_mod.build_plan(topo, wf, grouping, [topo.n],
                               list(range(topo.n)))
    cm = CostModel(topo, wf)
    assert cm.cost(loadbalance.balance(topo, wf, plan)) \
        <= cm.cost(plan) * (1 + 1e-9)
