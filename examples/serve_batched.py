"""Batched serving demo: continuous-batching decode for any assigned
architecture (smoke scale on CPU), reporting tokens/s, time-to-first-
token (p50/p95) and decode-wave occupancy — including the
sliding-window ring-buffer cache (mixtral/gemma2), recurrent-state
decode (rwkv6/jamba) and chunked prefill (--prefill-chunk: prompts are
ingested in bounded chunks riding along with decode rounds, so
admission never stalls the wave).

    PYTHONPATH=src python examples/serve_batched.py --arch mixtral-8x7b \
        --prefill-chunk 16
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import archs
from repro.core.plan import decode_wave
from repro.genserve import adapter as genserve
from repro.genserve.adapter import ttft_quantiles
from repro.models import transformer as T
from repro.rl.rollout import SamplerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--wave", type=int, default=4)
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked admission tokens per mixed round "
                         "(0 = one-shot prefill)")
    args = ap.parse_args()

    cfg = archs.get(args.arch, smoke=True)
    if cfg.is_encoder_only:
        raise SystemExit(f"{cfg.name}: encoder-only, no decode serving")
    if cfg.frontend == "features":
        print(f"note: {cfg.name} is a VLM; serving the text decoder only")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    key = jax.random.PRNGKey(1)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size, jnp.int32)
    wave = args.wave or decode_wave(args.batch)
    sampler = SamplerConfig(max_new_tokens=args.new_tokens, greedy=True)
    gen = lambda **kw: genserve.generate(params, cfg, prompts,
                                         jax.random.PRNGKey(2), sampler,
                                         wave=wave, decode_chunk=4,
                                         prefill_chunk=args.prefill_chunk,
                                         fast_path=False, **kw)
    gen()  # compile
    t0 = time.time()
    ro, stats = gen()   # uninstrumented: TTFT stamping syncs admission
    jax.block_until_ready(ro["sequences"])
    dt = time.time() - t0
    _, ttft_stats = gen(measure_ttft=True)
    windows = sorted({s.window for s in cfg.pattern if s.window})
    p50, p95 = ttft_quantiles(ttft_stats)
    admission = (f"chunked C={args.prefill_chunk}" if args.prefill_chunk
                 else "one-shot")
    print(f"arch={cfg.name} (windows={windows or 'full'}) "
          f"batch={args.batch} wave={stats['wave']} "
          f"prompt={args.prompt_len} new={args.new_tokens} "
          f"admission={admission}")
    if args.prefill_chunk:
        occ_label = f"busy occupancy {stats['busy_occupancy']:.2f}"
    else:
        occ_label = f"mean occupancy {stats['mean_occupancy']:.2f}"
    print(f"decode throughput: {args.batch * args.new_tokens / dt:.1f} "
          f"tok/s ({dt:.2f}s; {occ_label}; "
          f"ttft p50={p50 * 1e3:.1f}ms p95={p95 * 1e3:.1f}ms)")
    print("sample:", ro["sequences"][0, args.prompt_len:][:16].tolist())


if __name__ == "__main__":
    main()
