"""Batched serving demo: prefill + KV-cache decode for any assigned
architecture (smoke scale on CPU), reporting tokens/s — including the
sliding-window ring-buffer cache (mixtral/gemma2) and recurrent-state
decode (rwkv6/jamba).

    PYTHONPATH=src python examples/serve_batched.py --arch mixtral-8x7b
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import archs
from repro.models import transformer as T
from repro.models.sampling import greedy_decode


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--new-tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = archs.get(args.arch, smoke=True)
    if cfg.is_encoder_only:
        raise SystemExit(f"{cfg.name}: encoder-only, no decode serving")
    if cfg.frontend == "features":
        print(f"note: {cfg.name} is a VLM; serving the text decoder only")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    key = jax.random.PRNGKey(1)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size, jnp.int32)
    fn = jax.jit(lambda p, x: greedy_decode(p, cfg, x, args.new_tokens))
    toks = fn(params, prompts)  # compile
    t0 = time.time()
    toks = fn(params, prompts)
    toks.block_until_ready()
    dt = time.time() - t0
    windows = sorted({s.window for s in cfg.pattern if s.window})
    print(f"arch={cfg.name} (windows={windows or 'full'}) "
          f"batch={args.batch} prompt={args.prompt_len} "
          f"new={args.new_tokens}")
    print(f"decode throughput: {args.batch * args.new_tokens / dt:.1f} "
          f"tok/s ({dt:.2f}s)")
    print("sample:", toks[0, :16].tolist())


if __name__ == "__main__":
    main()
