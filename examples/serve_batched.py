"""Batched serving demo: continuous-batching decode for any assigned
architecture (smoke scale on CPU), reporting tokens/s and decode-wave
occupancy — including the sliding-window ring-buffer cache
(mixtral/gemma2) and recurrent-state decode (rwkv6/jamba).

    PYTHONPATH=src python examples/serve_batched.py --arch mixtral-8x7b
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import archs
from repro.core.plan import decode_wave
from repro.genserve import adapter as genserve
from repro.models import transformer as T
from repro.rl.rollout import SamplerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--wave", type=int, default=4)
    args = ap.parse_args()

    cfg = archs.get(args.arch, smoke=True)
    if cfg.is_encoder_only:
        raise SystemExit(f"{cfg.name}: encoder-only, no decode serving")
    if cfg.frontend == "features":
        print(f"note: {cfg.name} is a VLM; serving the text decoder only")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    key = jax.random.PRNGKey(1)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size, jnp.int32)
    wave = args.wave or decode_wave(args.batch)
    sampler = SamplerConfig(max_new_tokens=args.new_tokens, greedy=True)
    gen = lambda: genserve.generate(params, cfg, prompts,
                                    jax.random.PRNGKey(2), sampler,
                                    wave=wave, decode_chunk=4,
                                    fast_path=False)
    gen()  # compile
    t0 = time.time()
    ro, stats = gen()
    jax.block_until_ready(ro["sequences"])
    dt = time.time() - t0
    windows = sorted({s.window for s in cfg.pattern if s.window})
    print(f"arch={cfg.name} (windows={windows or 'full'}) "
          f"batch={args.batch} wave={stats['wave']} "
          f"prompt={args.prompt_len} new={args.new_tokens}")
    print(f"decode throughput: {args.batch * args.new_tokens / dt:.1f} "
          f"tok/s ({dt:.2f}s; mean occupancy "
          f"{stats['mean_occupancy']:.2f})")
    print("sample:", ro["sequences"][0, args.prompt_len:][:16].tolist())


if __name__ == "__main__":
    main()
