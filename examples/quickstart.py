"""Quickstart: schedule an RL workflow on a heterogeneous cluster.

    PYTHONPATH=src python examples/quickstart.py

Builds the paper's 64-GPU testbed (24xA100 + 24xL40S + 16xL4) under the
multi-country network scenario, searches for an execution plan with the
HetRL hybrid scheduler (nested SHA + EA), and compares it against the
verl-like and StreamRL-like baselines.
"""
import sys

sys.path.insert(0, "src")

from repro.core import baselines, simulator, topology, workflow
from repro.core.sha import HybridScheduler


def main():
    topo = topology.build_testbed("multi_country")
    wf = workflow.make_ppo(workflow.QWEN_8B)
    print(f"cluster: {topo.n} GPUs, "
          f"{len({d.region for d in topo.devices})} regions; "
          f"workflow: {wf.algorithm} x{wf.n_tasks} tasks, "
          f"{wf.samples_per_iter} samples/iter")

    sched = HybridScheduler(topo, wf, max_groupings=16,
                            max_sizes_per_grouping=4)
    result = sched.search(budget=300)
    print(f"\nHetRL plan: {result.cost:.1f}s per iteration "
          f"({wf.samples_per_iter / result.cost:.2f} samples/s)")
    print(f"  task grouping: {result.grouping}")
    print(f"  GPU group sizes: {result.sizes}")
    for g in result.plan.groups:
        names = [wf.task(t).name for t in g.tasks]
        specs = {}
        for d in g.devices:
            specs[topo.devices[d].spec.name] = \
                specs.get(topo.devices[d].spec.name, 0) + 1
        print(f"  {names} -> {specs}")
    for t in range(wf.n_tasks):
        dp, pp, tp = result.plan.parallel[t]
        print(f"  {wf.task(t).name:22s} dp={dp:2d} pp={pp} tp={tp}")

    sim = simulator.simulate(topo, wf, result.plan)
    print(f"\nevent-driven simulator: {sim.iteration_time:.1f}s/iter "
          f"({sim.throughput:.2f} samples/s)")

    r_verl = baselines.verl_scheduler(topo, wf)
    r_srl = baselines.streamrl_scheduler(topo, wf, budget=1024)
    print(f"\nbaselines: verl {r_verl.cost:.1f}s "
          f"({r_verl.cost / result.cost:.2f}x slower), "
          f"StreamRL {r_srl.cost:.1f}s "
          f"({r_srl.cost / result.cost:.2f}x slower)")


if __name__ == "__main__":
    main()
