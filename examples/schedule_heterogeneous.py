"""Scheduling deep-dive: all four network scenarios + the TPU-native pool,
HetRL SHA-EA vs ILP vs baselines, with async overlap and the event
timeline of the winning plan.

    PYTHONPATH=src python examples/schedule_heterogeneous.py [--fast]
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.core import baselines, simulator, topology, workflow
from repro.core.ilp import ilp_scheduler
from repro.core.sha import HybridScheduler


def schedule(topo, wf, budget):
    sched = HybridScheduler(topo, wf, max_groupings=12,
                            max_sizes_per_grouping=4)
    return sched.search(budget=budget)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    budget = 150 if args.fast else 400

    wf_sync = workflow.make_ppo(workflow.QWEN_8B, synchronous=True)
    wf_async = workflow.make_ppo(workflow.QWEN_8B, synchronous=False)

    print(f"{'scenario':22s} {'verl':>8s} {'streamrl':>9s} "
          f"{'hetrl':>8s} {'hetrl-async':>12s}")
    for scen in topology.SCENARIOS:
        topo = topology.build_testbed(scen)
        r_v = baselines.verl_scheduler(topo, wf_sync)
        r_s = baselines.streamrl_scheduler(topo, wf_sync, budget=1024)
        r_h = schedule(topo, wf_sync, budget)
        r_a = schedule(topo, wf_async, budget)
        print(f"{scen:22s} {r_v.cost:8.1f} {r_s.cost:9.1f} "
              f"{r_h.cost:8.1f} {r_a.cost:12.1f}")

    # TPU-native heterogeneous pool (DESIGN.md hardware adaptation)
    tpu = topology.build_tpu_pool(n_v5e=32, n_v4=16)
    r_tpu = schedule(tpu, wf_sync, budget)
    print(f"\nTPU pool (32x v5e + 16x v4 over DCN): {r_tpu.cost:.1f}s/iter, "
          f"grouping={r_tpu.grouping}")

    # small-instance exact optimum
    small = topology.build_testbed("single_region",
                                   counts={"A100": 4, "L4": 4})
    wf_small = workflow.make_grpo(workflow.QWEN_1_7B, global_batch=64)
    r_ilp = ilp_scheduler(small, wf_small, max_seconds=60)
    r_sha = schedule(small, wf_small, budget)
    print(f"\n8-GPU exact ILP optimum: {r_ilp.cost:.2f}s; SHA-EA: "
          f"{r_sha.cost:.2f}s (gap {100 * (r_sha.cost / r_ilp.cost - 1):.1f}%)")

    # timeline of the winning multi-country plan
    topo = topology.build_testbed("multi_country")
    r = schedule(topo, wf_async, budget)
    sim = simulator.simulate(topo, wf_async, r.plan, n_iterations=3)
    print(f"\nasync timeline (multi-country, 3 iterations, "
          f"steady-state {sim.iteration_time:.1f}s/iter):")
    for ev in sim.timeline:
        if ev.kind == "start":
            print(f"  t={ev.time:8.1f}s iter{ev.iteration} start "
                  f"{wf_async.task(ev.task).name}")


if __name__ == "__main__":
    main()
