"""End-to-end RL training driver: GRPO (or PPO) on the verifiable
integer-addition task, with the HetRL scheduler choosing the execution
plan for the device pool first (annotative on a single host).

    PYTHONPATH=src python examples/train_rl_e2e.py \
        --iters 200 --batch 16 --d-model 192 --layers 4

Reward (digit-level correctness) and greedy exact-match accuracy climb
within a few dozen iterations; checkpoints land in results/rl_ckpt.
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.checkpoint import io as ckpt
from repro.core import enumerate as enum_mod, topology, workflow
from repro.core.costmodel import CostModel
from repro.data.synthetic import AdditionTask, PromptDataset, VOCAB_SIZE
from repro.models.config import ModelConfig
from repro.rl.trainer import RLConfig, RLTrainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--algorithm", default="grpo", choices=["grpo", "ppo"])
    ap.add_argument("--iters", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--rollouts", type=int, default=8)
    ap.add_argument("--d-model", type=int, default=192)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--max-operand", type=int, default=9)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = ModelConfig(
        name="rl-actor", n_layers=args.layers, d_model=args.d_model,
        n_heads=max(args.d_model // 48, 2), n_kv_heads=2, head_dim=48,
        d_ff=args.d_model * 3, vocab_size=VOCAB_SIZE, dtype="float32")
    print(f"actor: {cfg.param_count():,} params")

    # --- scheduling phase: what would this workflow need on a cluster? ---
    topo = topology.build_testbed("single_region",
                                  counts={"A100": 4, "L4": 4})
    spec = workflow.LLMSpec.from_model_config(cfg)
    wf = workflow.make_workflow(args.algorithm, spec,
                                global_batch=args.batch,
                                n_rollouts=args.rollouts, seq_in=16,
                                seq_out=8)
    grouping = enum_mod.priority_groupings(wf)[0]
    plan = enum_mod.build_plan(topo, wf, grouping, [topo.n],
                               list(range(topo.n)))
    print(f"scheduler: colocated plan estimated at "
          f"{CostModel(topo, wf).cost(plan) * 1e3:.1f}ms/iter on the "
          f"8-GPU reference pool (executing locally on "
          f"{jax.device_count()} host device(s))")

    # --- RL training ---
    task = AdditionTask(max_operand=args.max_operand)
    rl = RLConfig(algorithm=args.algorithm, n_rollouts=args.rollouts,
                  max_new_tokens=task.max_answer_len, lr=args.lr,
                  kl_beta=0.002)
    trainer = RLTrainer(cfg, rl, task, jax.random.PRNGKey(0), plan=plan)
    ds = iter(PromptDataset(task, batch=args.batch, seed=1))
    eval_rng = np.random.default_rng(7)
    eval_prompts, eval_answers = task.sample_batch(eval_rng, 64)

    key = jax.random.PRNGKey(42)
    t0 = time.time()
    for it in range(args.iters):
        prompts, answers = next(ds)
        key, k = jax.random.split(key)
        m = trainer.iteration(prompts, answers, k)
        if it % 10 == 0 or it == args.iters - 1:
            acc = trainer.evaluate(eval_prompts, eval_answers,
                                   jax.random.PRNGKey(1))
            print(f"iter {it:4d} reward={m['reward_mean']:.3f} "
                  f"kl={m['kl']:.3f} acc={acc:.2f} "
                  f"sync={m['sync_gb'] * 1e3:.1f}MB "
                  f"({time.time() - t0:.0f}s)")
        if args.ckpt_every and it and it % args.ckpt_every == 0:
            n = ckpt.save("results/rl_ckpt/actor.msgpack", trainer.actor)
            print(f"  checkpointed actor ({n / 1e6:.1f} MB)")
    acc = trainer.evaluate(eval_prompts, eval_answers, jax.random.PRNGKey(1))
    print(f"final greedy exact-match accuracy: {acc:.2f}")


if __name__ == "__main__":
    main()
