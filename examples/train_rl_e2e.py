"""End-to-end RL training driver: GRPO (or PPO) on the verifiable
integer-addition task, with the HetRL scheduler choosing the execution
plan for the device pool and the plan-driven engine executing it:

    scheduler search -> Plan -> engine execution -> measured vs predicted

    PYTHONPATH=src python examples/train_rl_e2e.py \
        --iters 200 --batch 16 --d-model 192 --layers 4

Reward (digit-level correctness) and greedy exact-match accuracy climb
within a few dozen iterations; checkpoints land in results/rl_ckpt.  At
the end the measured iteration time from the engine's event timeline is
compared against the cost model's prediction (Fig-7 style).
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.checkpoint import io as ckpt
from repro.core import topology, workflow
from repro.core.plan import check_constraints
from repro.core.sha import HybridScheduler
from repro.data.synthetic import AdditionTask, PromptDataset, VOCAB_SIZE
from repro.models.config import ModelConfig
from repro.rl.trainer import RLConfig, RLTrainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--algorithm", default="grpo", choices=["grpo", "ppo"])
    ap.add_argument("--iters", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--rollouts", type=int, default=8)
    ap.add_argument("--d-model", type=int, default=192)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--max-operand", type=int, default=9)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--async", dest="asynchronous", action="store_true",
                    help="one-step off-policy double-buffered execution")
    ap.add_argument("--search-budget", type=int, default=120,
                    help="scheduler budget in cost-model evaluations")
    args = ap.parse_args()

    cfg = ModelConfig(
        name="rl-actor", n_layers=args.layers, d_model=args.d_model,
        n_heads=max(args.d_model // 48, 2), n_kv_heads=2, head_dim=48,
        d_ff=args.d_model * 3, vocab_size=VOCAB_SIZE, dtype="float32")
    print(f"actor: {cfg.param_count():,} params")

    # --- scheduling phase: search the plan space for the reference pool ---
    task = AdditionTask(max_operand=args.max_operand)
    topo = topology.build_testbed("single_region",
                                  counts={"A100": 4, "L4": 4})
    spec = workflow.LLMSpec.from_model_config(cfg)
    wf = workflow.make_workflow(args.algorithm, spec,
                                synchronous=not args.asynchronous,
                                global_batch=args.batch,
                                n_rollouts=args.rollouts,
                                seq_in=task.prompt_len,
                                seq_out=task.max_answer_len)
    sched = HybridScheduler(topo, wf, max_groupings=8,
                            max_sizes_per_grouping=4)
    r = sched.search(budget=args.search_budget)
    ok, msg = check_constraints(topo, wf, r.plan)
    assert ok, msg
    print(f"scheduler: SHA-EA searched {r.evals} evals; best plan "
          f"grouping={r.grouping} estimated at {r.cost * 1e3:.3f}ms/iter "
          f"on the 8-GPU reference pool (executing locally on "
          f"{jax.device_count()} host device(s))")

    # --- RL training, executed by the plan-driven engine ---
    rl = RLConfig(algorithm=args.algorithm, n_rollouts=args.rollouts,
                  max_new_tokens=task.max_answer_len, lr=args.lr,
                  kl_beta=0.002, asynchronous=args.asynchronous)
    trainer = RLTrainer(cfg, rl, task, jax.random.PRNGKey(0), plan=r.plan,
                        topo=topo, wf=wf)
    ds = iter(PromptDataset(task, batch=args.batch, seed=1))
    eval_rng = np.random.default_rng(7)
    eval_prompts, eval_answers = task.sample_batch(eval_rng, 64)

    key = jax.random.PRNGKey(42)
    t0 = time.time()
    for it in range(args.iters):
        prompts, answers = next(ds)
        key, k = jax.random.split(key)
        m = trainer.iteration(prompts, answers, k)
        if it % 10 == 0 or it == args.iters - 1:
            acc = trainer.evaluate(eval_prompts, eval_answers,
                                   jax.random.PRNGKey(1))
            print(f"iter {it:4d} reward={m['reward_mean']:.3f} "
                  f"kl={m['kl']:.3f} acc={acc:.2f} "
                  f"sync={m['sync_gb'] * 1e3:.1f}MB "
                  f"({time.time() - t0:.0f}s)")
        if args.ckpt_every and it and it % args.ckpt_every == 0:
            n = ckpt.save("results/rl_ckpt/actor.msgpack", trainer.actor)
            print(f"  checkpointed actor ({n / 1e6:.1f} MB)")
    acc = trainer.evaluate(eval_prompts, eval_answers, jax.random.PRNGKey(1))
    print(f"final greedy exact-match accuracy: {acc:.2f}")

    # --- measured vs cost-model iteration time (Fig-7 style) ---
    cmp = trainer.engine.compare_with_simulator()
    print(f"engine: measured {cmp['measured_iter_s'] * 1e3:.1f}ms/iter "
          f"on this host vs cost-model prediction "
          f"{cmp['predicted_iter_s'] * 1e3:.3f}ms/iter for the reference "
          f"pool (ratio {cmp['ratio']:.2f}; the plan's colocation and "
          f"sync path drive both timelines)")

    # --- decode-wave occupancy: genserve measured vs cost model ---
    occ = trainer.engine.wave_occupancy_summary()
    n_waves = len(trainer.engine.wave_timeline) // 2
    print(f"genserve: {n_waves} wave rounds recorded; measured mean "
          f"slot occupancy {occ['measured_occupancy']:.2f} vs cost-model "
          f"decode-wave prediction {occ.get('predicted_occupancy', 0):.2f} "
          f"(ratio {occ.get('ratio', float('nan')):.2f})")


if __name__ == "__main__":
    main()
