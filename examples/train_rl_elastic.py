"""Elastic RL training driver (§6 online redeployment, end to end):

    scheduler search -> Plan -> engine-executed GRPO training
      -> injected topology drift (device loss / link degradation)
      -> warm-started reschedule at the iteration boundary
      -> checkpoint -> plan swap (Engine.apply_plan) -> continued training

    PYTHONPATH=src python examples/train_rl_elastic.py \
        --iters 16 --drift drop_tail --drift-at 6

Trainer/optimizer state crosses the swap untouched (weight_version stays
monotone, the loss curve does not reset), and the run ends with a
measured-vs-predicted iteration-time row per plan epoch — the estimate
never straddles the swap.  ``--require-switch`` makes the run exit
non-zero unless the drift actually produced an applied swap (CI smoke).
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.core import topology, workflow
from repro.core.plan import check_constraints
from repro.core.sha import HybridScheduler
from repro.data.synthetic import AdditionTask, PromptDataset, VOCAB_SIZE
from repro.engine.elastic import ElasticConfig, ElasticController
from repro.models.config import ModelConfig
from repro.rl.trainer import RLConfig, RLTrainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=16)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--rollouts", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=96)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--async", dest="asynchronous", action="store_true")
    ap.add_argument("--drift", default="drop_tail",
                    choices=topology.DRIFT_SCENARIOS)
    ap.add_argument("--drift-at", type=int, default=None,
                    help="iteration the drift fires at (default iters//3)")
    ap.add_argument("--search-budget", type=int, default=120)
    ap.add_argument("--reschedule-budget", type=int, default=150,
                    help="warm-started budget for the elastic reschedule")
    ap.add_argument("--ckpt-dir", default="results/elastic_ckpt")
    ap.add_argument("--require-switch", action="store_true",
                    help="exit non-zero unless a plan swap was applied")
    args = ap.parse_args()

    cfg = ModelConfig(
        name="rl-elastic", n_layers=args.layers, d_model=args.d_model,
        n_heads=max(args.d_model // 48, 2), n_kv_heads=2, head_dim=48,
        d_ff=args.d_model * 3, vocab_size=VOCAB_SIZE, dtype="float32")
    task = AdditionTask(max_operand=9)

    # --- scheduling phase on the healthy reference pool ---
    topo = topology.build_testbed("single_region",
                                  counts={"A100": 4, "L4": 4})
    spec = workflow.LLMSpec.from_model_config(cfg)
    wf = workflow.make_workflow("grpo", spec,
                                synchronous=not args.asynchronous,
                                global_batch=args.batch,
                                n_rollouts=args.rollouts,
                                seq_in=task.prompt_len,
                                seq_out=task.max_answer_len)
    sched = HybridScheduler(topo, wf, max_groupings=8,
                            max_sizes_per_grouping=4)
    r = sched.search(budget=args.search_budget)
    ok, msg = check_constraints(topo, wf, r.plan)
    assert ok, msg
    print(f"scheduler: grouping={r.grouping} predicted "
          f"{r.cost * 1e3:.3f}ms/iter on the healthy pool")

    # --- trainer + elasticity loop ---
    rl = RLConfig(algorithm="grpo", n_rollouts=args.rollouts,
                  max_new_tokens=task.max_answer_len, lr=args.lr,
                  kl_beta=0.002, asynchronous=args.asynchronous)
    trainer = RLTrainer(cfg, rl, task, jax.random.PRNGKey(0), plan=r.plan,
                        topo=topo, wf=wf)
    drift_at = args.drift_at if args.drift_at is not None \
        else max(args.iters // 3, 1)
    schedule = topology.drift_scenario(args.drift, topo, at=drift_at)
    controller = ElasticController(
        trainer, schedule,
        ElasticConfig(budget=args.reschedule_budget,
                      ckpt_dir=args.ckpt_dir))

    ds = iter(PromptDataset(task, batch=args.batch, seed=1))
    key = jax.random.PRNGKey(42)
    t0 = time.time()
    wv_trace = []
    for it in range(args.iters):
        prompts, answers = next(ds)
        key, k = jax.random.split(key)
        m = trainer.iteration(prompts, answers, k)
        wv_trace.append(trainer.weight_version)
        print(f"iter {it:3d} epoch={trainer.engine.epoch} "
              f"reward={m['reward_mean']:.3f} loss={m.get('loss', 0):.4f} "
              f"wv={trainer.weight_version} ({time.time() - t0:.0f}s)")
        rec = controller.poll(it)
        if rec is not None:
            d = rec.decision
            print(f"  drift detected -> reschedule ({rec.reschedule_s:.1f}s "
                  f"wall): switch={d.switch} "
                  f"incumbent={d.old_cost * 1e3:.3f}ms/iter "
                  f"challenger={d.new_cost * 1e3:.3f}ms/iter "
                  f"transition={d.transition_cost_s * 1e3:.3f}ms "
                  f"(amortized over {d.amortization_iters} iters); "
                  f"checkpoint {rec.ckpt_bytes / 1e6:.1f}MB -> "
                  f"{rec.ckpt_path}")
            if rec.applied:
                print(f"  plan swapped at the iteration boundary: now "
                      f"epoch {rec.epoch}, trainer state carried "
                      f"(wv={trainer.weight_version})")

    # --- invariants the §6 story promises ---
    assert all(b >= a for a, b in zip(wv_trace, wv_trace[1:])), \
        "weight_version must stay monotone across the swap"

    print("\nper plan-epoch measured vs predicted (never straddles a swap):")
    for row in trainer.engine.epoch_report():
        print(f"  epoch {row['epoch']}: {row['iterations']:3d} iters  "
              f"measured {row['measured_iter_s'] * 1e3:8.1f}ms/iter  "
              f"predicted {row['predicted_iter_s'] * 1e3:8.3f}ms/iter")

    swaps = controller.swaps
    print(f"\n{len(controller.records)} drift reaction(s), "
          f"{len(swaps)} applied swap(s)")
    if args.require_switch and not swaps:
        print("FAIL: --require-switch set but no plan swap was applied")
        raise SystemExit(1)
    print("done")


if __name__ == "__main__":
    main()
