"""Observability walkthrough: trace a training run, read the span
report, calibrate the cost model from the measured timeline.

    PYTHONPATH=src python examples/trace_obs.py

Runs a few plan-driven GRPO iterations with span tracing enabled,
prints the per-span aggregate report, exports a Chrome-trace JSON
(open it at https://ui.perfetto.dev or in chrome://tracing), dumps the
metrics-registry snapshot, and fits the cost-model calibration that
turns the engine's measured-vs-predicted iteration ratio from "orders
of magnitude" into "within a few x" (the paper's Fig. 7 usable
regime).

Everything here also works on any launcher via the environment:
``REPRO_TRACE=trace.json`` enables tracing and exports at exit, and
``REPRO_METRICS=metrics.json`` does the same for the registry.
Validate any emitted trace with ``python -m repro.obs.trace
trace.json``.
"""
import sys

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.data.synthetic import AdditionTask, VOCAB_SIZE
from repro.models.config import ModelConfig
from repro.obs import calibrate as obs_cal
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.rl.trainer import RLConfig, RLTrainer


def main():
    obs_trace.enable()

    cfg = ModelConfig(name="obs-demo", n_layers=1, d_model=32, n_heads=2,
                      n_kv_heads=2, d_ff=64, vocab_size=VOCAB_SIZE,
                      dtype="float32")
    task = AdditionTask(max_operand=9)
    trainer = RLTrainer(cfg, RLConfig(algorithm="grpo", n_rollouts=2,
                                      max_new_tokens=task.max_answer_len),
                        task, jax.random.PRNGKey(0))

    key = jax.random.PRNGKey(42)
    for i in range(4):
        prompts, answers = task.sample_batch(np.random.default_rng(i), 2)
        key, k = jax.random.split(key)
        m = trainer.iteration(prompts, answers, k)
        print(f"iter {i} reward={m['reward_mean']:.3f}")

    print("\n-- span report " + "-" * 45)
    print(obs_trace.report())

    trace_path = obs_trace.export_chrome("results/trace_obs.json")
    print(f"\nchrome trace -> {trace_path} "
          f"(open in https://ui.perfetto.dev)")
    errors = obs_trace.validate_file(trace_path)
    print(f"schema check: {'OK' if not errors else errors}")

    print("\n-- metrics snapshot " + "-" * 40)
    snap = obs_metrics.snapshot()
    for name in sorted(snap):
        v = snap[name]
        if isinstance(v, dict):
            print(f"{name}: count={v['count']} mean={v['mean']:.4g} "
                  f"p95={v['p95']:.4g}")
        else:
            print(f"{name}: {v}")

    print("\n-- calibration " + "-" * 45)
    cal = obs_cal.fit_from_engine(trainer.engine, skip_iterations=1)
    raw = trainer.engine.compare_with_simulator()
    fixed = trainer.engine.compare_with_simulator(
        cost_model=cal.cost_model(trainer.engine.topo, trainer.wf))
    print(f"per-class scales: { {c: round(s, 1) for c, s in cal.class_scale.items()} }")
    print(f"measured/predicted iteration ratio: "
          f"{raw['ratio']:.3g} raw -> {fixed['ratio']:.3g} calibrated")


if __name__ == "__main__":
    main()
