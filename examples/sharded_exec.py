"""Sharded plan execution on a forced 8-device host (CPU CI analogue).

Forces ``--xla_force_host_platform_device_count=8`` *before* importing
jax, builds a gen|rest disaggregated plan whose training group runs
DP=2/TP=2 on its own 4 devices while generation runs DP=2/TP=2 on the
other 4, and validates the sharded execution path end to end:

- group-aware folding is injective: GEN and TRAIN land on disjoint real
  device sets with zero collisions;
- the DP=2/TP=2 sharded train step matches an unsharded single-device
  run numerically (loss within tolerance) and greedy generation is
  token-identical;
- async mode runs the GEN lane wall-clock concurrent with the training
  stages (``overlap_active``), the one-step-staleness invariant intact;
- ``compare_with_simulator`` prices the realized parallelization.

Run:  python examples/sharded_exec.py [--iters 4] [--json]
"""
import argparse
import json
import os
import sys

_FLAG = "--xla_force_host_platform_device_count=8"
if "xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = \
        (os.environ.get("XLA_FLAGS", "") + " " + _FLAG).strip()

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))

import jax                                              # noqa: E402
import numpy as np                                      # noqa: E402

from repro.core import enumerate as enum_mod            # noqa: E402
from repro.core import topology, workflow               # noqa: E402
from repro.core.plan import check_constraints           # noqa: E402
from repro.data.synthetic import AdditionTask, VOCAB_SIZE  # noqa: E402
from repro.models.config import ModelConfig             # noqa: E402
from repro.rl.trainer import RLConfig, RLTrainer        # noqa: E402

KEY = jax.random.PRNGKey(0)


def tiny_cfg():
    return ModelConfig(name="sharded-tiny", n_layers=2, d_model=64,
                       n_heads=2, n_kv_heads=2, head_dim=32, d_ff=128,
                       vocab_size=VOCAB_SIZE, dtype="float32")


def build_plan_8dev(wf):
    """gen | rest over 8 plan devices: generation on 0-3, the inference
    and training tasks on 4-7, actor training explicitly DP=2/TP=2."""
    topo = topology.build_testbed("single_region",
                                  counts={"A100": 4, "L4": 4})
    grouping = next(g for g in enum_mod.priority_groupings(wf)
                    if len(g) == 2 and any(
                        wf.task(t).kind == workflow.TaskKind.GEN
                        for t in min(g, key=len)))
    gen_gi = next(gi for gi, g in enumerate(grouping)
                  if any(wf.task(t).kind == workflow.TaskKind.GEN
                         for t in g))
    sizes = [4, 4]
    parallel = {}
    for t in range(wf.n_tasks):
        kind = wf.task(t).kind
        parallel[t] = (2, 1, 2) if kind in (workflow.TaskKind.GEN,
                                            workflow.TaskKind.TRAIN) \
            else (4, 1, 1)
    order = list(range(8)) if gen_gi == 0 else \
        list(range(4, 8)) + list(range(4))
    plan = enum_mod.build_plan(topo, wf, grouping, sizes, order,
                               parallel=parallel)
    ok, msg = check_constraints(topo, wf, plan)
    assert ok, msg
    return topo, plan


def make_trainer(devices=None, greedy=False):
    cfg = tiny_cfg()
    task = AdditionTask(max_operand=9)
    # whitening off: it normalizes by the in-group advantage std, which
    # amplifies TP reduction-order noise (~1e-6) to O(1) when a group's
    # rewards are nearly uniform — parity would compare amplified noise
    rl = RLConfig(algorithm="grpo", n_rollouts=4, max_new_tokens=4,
                  asynchronous=True, greedy=greedy,
                  whiten_advantages=False)
    wf = workflow.make_workflow("grpo", workflow.LLMSpec.from_model_config(cfg),
                                synchronous=False, n_rollouts=rl.n_rollouts,
                                seq_in=task.prompt_len,
                                seq_out=rl.max_new_tokens, global_batch=1)
    topo, plan = build_plan_8dev(wf)
    trainer = RLTrainer(cfg, rl, task, KEY, plan=plan, topo=topo, wf=wf,
                        devices=devices)
    return trainer, topo, plan


def run(trainer, iters, batch=4, seed=0):
    """Same prompt/rng stream for every trainer — the runs are
    numerically comparable iteration by iteration."""
    task = trainer.task
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(7)
    metrics, gen_tokens = [], []
    for _ in range(iters):
        prompts, answers = task.sample_batch(rng, batch)
        key, k = jax.random.split(key)
        metrics.append(trainer.iteration(prompts, answers, k))
        pend = trainer.engine.pipeline._pending
        gen_tokens.append(np.asarray(pend["rollout"]["gen_tokens"])
                          if pend is not None else None)
    return metrics, gen_tokens


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--iters", type=int, default=4)
    # first trained iteration matches to ~1e-6; later iterations carry
    # compounded float32 TP reduction-order drift through the updated
    # params (observed ~1% by iteration 3 on the tiny model)
    ap.add_argument("--loss-rtol", type=float, default=5e-2)
    ap.add_argument("--loss-atol", type=float, default=1e-4)
    ap.add_argument("--json", action="store_true",
                    help="emit a machine-readable summary on stdout")
    args = ap.parse_args()

    n_dev = jax.device_count()
    assert n_dev >= 8, \
        f"need 8 forced host devices, got {n_dev} (XLA_FLAGS lost?)"

    # sharded run on all 8 devices, unsharded baseline pinned to one
    sharded, topo, plan = make_trainer()
    baseline, _, _ = make_trainer(devices=[jax.devices()[0]])

    eng = sharded.engine
    gen_t, train_t = eng.ctx.gen_task, eng.ctx.actor_train

    # -- placement: disjoint groups, zero collisions, DP=2/TP=2 --------
    folding = eng.ctx.folding
    assert folding.n_collisions == 0, folding.collisions
    assert not folding.oversubscribed
    gen_pl, train_pl = eng.placements[gen_t], eng.placements[train_t]
    gen_ids = {d.id for d in gen_pl.local_devices}
    train_ids = {d.id for d in train_pl.local_devices}
    assert gen_ids.isdisjoint(train_ids), (gen_ids, train_ids)
    assert train_pl.mesh_shape == (2, 2), train_pl.mesh_shape
    assert (train_pl.dp_eff, train_pl.tp_eff) == (2, 2)
    assert gen_pl.mesh_shape == (2, 2)
    assert eng.overlap_active(), "disjoint async groups must overlap"
    assert not baseline.engine.overlap_active()
    base_pl = baseline.engine.placements[train_t]
    assert not base_pl.sharded and base_pl.n_devices == 1

    # -- numerics: sharded == unsharded --------------------------------
    # temperature sampling: in-group reward variance makes the GRPO
    # advantages (and so the train-step loss) non-trivially nonzero —
    # the parity below actually exercises the DP=2/TP=2 update
    m_sh, g_sh = run(sharded, args.iters)
    m_bl, g_bl = run(baseline, args.iters)

    assert m_sh[0].get("pipeline_fill") == 1.0   # async fill iteration
    for it, (a, b) in enumerate(zip(g_sh, g_bl)):
        assert a is not None and b is not None
        assert np.array_equal(a, b), \
            f"iter {it}: sampled generation diverged between meshes"
    losses_sh = [m["loss"] for m in m_sh[1:]]
    losses_bl = [m["loss"] for m in m_bl[1:]]
    assert any(abs(x) > 1e-6 for x in losses_sh), \
        "degenerate run: every loss is zero, parity would be vacuous"
    np.testing.assert_allclose(losses_sh, losses_bl,
                               rtol=args.loss_rtol, atol=args.loss_atol)
    rewards_sh = [m["reward_mean"] for m in m_sh[1:]]
    rewards_bl = [m["reward_mean"] for m in m_bl[1:]]
    np.testing.assert_allclose(rewards_sh, rewards_bl, rtol=1e-6)

    # -- greedy decode: token-identical across meshes ------------------
    greedy_sh, _, _ = make_trainer(greedy=True)
    greedy_bl, _, _ = make_trainer(devices=[jax.devices()[0]], greedy=True)
    _, gg_sh = run(greedy_sh, 2)
    _, gg_bl = run(greedy_bl, 2)
    for it, (a, b) in enumerate(zip(gg_sh, gg_bl)):
        assert np.array_equal(a, b), \
            f"iter {it}: greedy generation diverged between meshes"

    # async one-step staleness intact under the overlapped walk
    for r in eng.pipeline.records[1:]:
        assert r.weight_version - r.gen_version == 1, r

    cmp = eng.compare_with_simulator()
    occ = eng.wave_occupancy_summary()
    summary = {
        "devices": n_dev,
        "gen_devices": sorted(gen_ids),
        "train_devices": sorted(train_ids),
        "train_mesh": list(train_pl.mesh_shape),
        "folding_collisions": folding.n_collisions,
        "overlap_active": eng.overlap_active(),
        "loss_sharded": losses_sh,
        "loss_baseline": losses_bl,
        "tokens_identical": True,
        "measured_iter_s": cmp["measured_iter_s"],
        "predicted_iter_s": cmp["predicted_iter_s"],
        "predicted_iter_realized_s": cmp["predicted_iter_realized_s"],
        "tp_shrunk": cmp["tp_shrunk"],
        "overlap_honest": occ.get("overlap_honest", 1.0),
    }
    if args.json:
        print(json.dumps(summary))
    else:
        print(f"devices: {n_dev}  gen on {sorted(gen_ids)}, "
              f"train on {sorted(train_ids)} "
              f"(mesh {train_pl.mesh_shape}, collisions "
              f"{folding.n_collisions}, overlap {eng.overlap_active()})")
        for it, (ls, lb) in enumerate(zip(losses_sh, losses_bl), start=1):
            print(f"iter {it}: loss sharded={ls:+.6f} "
                  f"baseline={lb:+.6f}  delta={ls - lb:+.2e}")
        print("greedy generation token-identical across all iterations")
        print(f"measured {cmp['measured_iter_s']:.4f}s/iter, "
              f"predicted {cmp['predicted_iter_s']:.4f}s "
              f"(realized {cmp['predicted_iter_realized_s']:.4f}s, "
              f"tp_shrunk={bool(cmp['tp_shrunk'])})")
        print("sharded execution parity OK")
    return summary


if __name__ == "__main__":
    main()
